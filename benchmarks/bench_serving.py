"""Serving-tier load benchmark: drive the continuous-batching scheduler
through the three committed traffic scenarios on the deterministic
virtual-clock simulator (src/repro/serving/simulator.py), and the
replicated fleet (src/repro/serving/fleet.py) through the six committed
fleet scenarios (``fleet_faultstorm`` runs the seeded fault storm under
the full resilience policy and feeds the gated ``serving_resilience``
BENCH section via ``bench_resilience()``; ``fleet_cached`` runs the
Zipf-skewed artifact-cache storm and feeds the gated ``serving_cache``
section via ``bench_cache()``).

Every number here is *virtual-clock*, derived from seeded arrivals and
the modeled-bytes service model — two runs with the same seed are
byte-identical on any machine, which is why the ``serving`` and
``serving_fleet`` sections of BENCH_2.json are gated ABSOLUTELY by
benchmarks/check_regression.py (no machine normalization: these keys
cannot drift with runner speed, only with scheduler/router behavior).

    PYTHONPATH=src python -m benchmarks.bench_serving --seed 0
    PYTHONPATH=src python -m benchmarks.bench_serving --scenario overload --json-out SUMMARY.json
    PYTHONPATH=src python -m benchmarks.bench_serving --fleet           # fleet scenarios
    PYTHONPATH=src python -m benchmarks.bench_serving --soak 3600   # CI's virtual-hour soak

``--json-out`` writes the full per-scenario summaries (the golden-trace
payloads); ``benchmarks.run serving`` / ``benchmarks.run serving_fleet``
consume ``bench()`` / ``bench_fleet()`` for the BENCH_2.json rows.
``--soak H`` stretches the horizon to H virtual seconds and asserts
conservation + shedding invariants instead of printing rows — the CI
serving job runs a one-virtual-hour soak in about a minute of CPU.
"""

from __future__ import annotations

import argparse
import json
import sys

# benchmarks/ is run both as a module (python -m benchmarks.bench_serving)
# and imported by benchmarks.run; repro comes from PYTHONPATH=src.


def _engine():
    """The canonical trace engine (simulator.reference_engine): the
    benchmark exercises the scheduler, not the kernels, so the model and
    volumes stay tiny and execution is modeled (execute=False in the
    presets)."""
    from repro.serving.simulator import reference_engine

    return reference_engine()


def run_scenarios(scenarios, seed: int = 0, horizon_s=None):
    """name -> summary dict for each requested scenario preset."""
    from repro.serving import simulator as sim

    out = {}
    for name in scenarios:
        engine = _engine()
        rep = sim.simulate(engine, sim.preset(name, seed=seed, horizon_s=horizon_s))
        out[name] = rep.summary()
    return out


def bench(seed: int = 0) -> list:
    """(name, us_per_call, hbm_bytes_modeled, note) rows for benchmarks.run
    — the gated BENCH_2.json ``serving`` section. ``us_per_call`` carries
    the virtual-clock latency percentile in microseconds (deterministic,
    so the gate is absolute); ``hbm_bytes_modeled`` is None (the traffic
    section already gates modeled bytes per backend)."""
    from repro.serving import simulator as sim

    rows = []
    for name, s in run_scenarios(sim.PRESETS, seed=seed).items():
        lat = s["latency_ms"]
        req = s["requests"]
        note = (
            f"served={req['completed'] + req['demoted']}"
            f";demoted={req['demoted']};refused={req['refused']}"
        )
        rows.append((f"serving_{name}_p50", lat["p50"] * 1e3, None, note))
        rows.append((f"serving_{name}_p99", lat["p99"] * 1e3, None, note))
        rows.append(
            (
                f"serving_{name}_wait_p99_interactive",
                s["classes"]["interactive"]["queue_wait_ms"]["p99"] * 1e3,
                None,
                "priority-protected class",
            )
        )
    return rows


def bench_batched(seed: int = 0) -> list:
    """(name, us_per_call, hbm_bytes_modeled, note) rows for the gated
    BENCH_2.json ``batched`` section — the N-volume batch axis made
    visible in two families of deterministic keys:

      * ``batched_<backend>_b{1,2,4}``: modeled HBM bytes of one
        gwm_light 256^3 forward at each batch size per backend
        (us_per_call rides at 0.0 — these are analytic byte rows, gated
        by the any-growth hbm rule). Sub-linear growth across b1/b2/b4
        IS the headline bugfix: the weight stream amortizes, so b4 is
        strictly under 4x b1 for every backend with a weight term;
      * ``serving_<preset>_batched_p{50,99}``: virtual-clock latency of
        each committed load scenario re-run with
        ``SchedulerConfig.batched_dispatch=True`` on the SAME seed and
        trace — the overload pair against ``serving_overload_p{50,99}``
        is the acceptance comparison (batched p99 must not exceed the
        serialized-dispatch p99).
    """
    from repro.core.meshnet import PAPER_MODELS
    from repro.serving import simulator as sim
    from repro.telemetry import traffic

    cfg = PAPER_MODELS["gwm_light"]
    vol = (256, 256, 256)
    byte_models = (
        ("xla", traffic.meshnet_xla_bytes),
        ("pallas_fused", traffic.meshnet_fused_bytes),
        ("pallas_megakernel", traffic.meshnet_megakernel_bytes),
        ("streaming", traffic.meshnet_streaming_bytes),
    )
    rows = []
    for name, fn in byte_models:
        b1 = fn(cfg, vol)
        for n in (1, 2, 4):
            bn = fn(cfg, vol, batch=n)
            rows.append(
                (
                    f"batched_{name}_b{n}",
                    0.0,
                    bn,
                    f"gwm_light 256^3; {bn / (n * b1):.4f}x of {n} serial forwards",
                )
            )
    scenarios = [f"{p}_batched" for p in sim.PRESETS]
    for name, s in run_scenarios(scenarios, seed=seed).items():
        lat = s["latency_ms"]
        req = s["requests"]
        note = (
            f"served={req['completed'] + req['demoted']}"
            f";demoted={req['demoted']};refused={req['refused']}"
            f";conserved={req['conserved']}"
        )
        rows.append((f"serving_{name}_p50", lat["p50"] * 1e3, None, note))
        rows.append((f"serving_{name}_p99", lat["p99"] * 1e3, None, note))
    return rows


def run_fleet_scenarios(scenarios, seed: int = 0, horizon_s=None):
    """name -> summary dict for each requested fleet preset."""
    from repro.serving import fleet as fl

    out = {}
    for name in scenarios:
        rep = fl.simulate_fleet(fl.fleet_preset(name, seed=seed, horizon_s=horizon_s))
        out[name] = rep.summary()
    return out


def bench_fleet(seed: int = 0) -> list:
    """(name, us_per_call, hbm_bytes_modeled, note) rows for the gated
    BENCH_2.json ``serving_fleet`` section — virtual-clock percentiles
    per fleet scenario, plus the two acceptance keys the single-server
    overload golden is compared against: the 4-replica fleet's
    interactive p99 (must stay interactive-class) and its queue-full
    refusal count (must stay strictly below the single server's 693,
    carried in the us_per_call slot so growth is absolutely gated)."""
    from repro.serving import fleet as fl

    rows = []
    summaries = run_fleet_scenarios(fl.FLEET_PRESETS, seed=seed)
    for name, s in summaries.items():
        lat = s["latency_ms"]
        req = s["requests"]
        aff = s["affinity"]
        note = (
            f"replicas={s['replicas']['created']}"
            f";redispatched={req['redispatched']};refused={req['refused']}"
            f";affinity_hit_rate={aff['hit_rate']}"
        )
        rows.append((f"serving_{name}_p50", lat["p50"] * 1e3, None, note))
        rows.append((f"serving_{name}_p99", lat["p99"] * 1e3, None, note))
    ov = summaries["fleet_overload"]
    rows.append(
        (
            "serving_fleet_overload_p99_interactive",
            ov["classes"]["interactive"]["latency_ms"]["p99"] * 1e3,
            None,
            "acceptance: < 5 virtual seconds on 4 replicas",
        )
    )
    rows.append(
        (
            "serving_fleet_overload_refused",
            float(ov["requests"]["refused"]),
            None,
            "acceptance: strictly below single-server overload (693)",
        )
    )
    return rows


def bench_resilience(seed: int = 0) -> list:
    """(name, us_per_call, hbm_bytes_modeled, note) rows for the gated
    BENCH_2.json ``serving_resilience`` section — the fault-storm
    acceptance scenario reduced to deterministic virtual-clock keys where
    GROWTH means the resilience layer got worse (check_regression gates
    virtual sections on growth only, so every key here is
    lower-is-better): unrecovered retryable faults, timeout reaps,
    lost/double-served requests (must stay 0), and the storm's e2e
    latency tail. Hedge/breaker activity rides in the notes column."""
    s = run_fleet_scenarios(["fleet_faultstorm"], seed=seed)["fleet_faultstorm"]
    req = s["requests"]
    r = s["resilience"]
    lost = req["arrived"] - (
        req["refused"] + req["no_replica"] + req["completed"]
        + req["demoted"] + sum(req["rejected"].values())
    )
    note = (
        f"retries={r['retries']};hedges={r['hedges']}"
        f";hedge_wins={r['hedge_wins']}"
        f";breaker_trips={r['breaker']['trips']}"
        f";recovery_rate={r['recovery_rate']}"
    )
    return [
        (
            "resilience_faultstorm_unrecovered",
            float(r["faulted_requests"] - r["recovered_requests"]),
            None,
            note,
        ),
        (
            "resilience_faultstorm_timeouts",
            float(r["faults"]["timeout"]),
            None,
            "stuck members reaped at the class bound",
        ),
        (
            "resilience_faultstorm_lost",
            float(lost),
            None,
            "acceptance: zero lost requests",
        ),
        (
            "resilience_faultstorm_double_served",
            float(req["served_twice"]),
            None,
            "acceptance: zero double-serves (hedge races included)",
        ),
        (
            "resilience_faultstorm_p99",
            s["latency_ms"]["p99"] * 1e3,
            None,
            note,
        ),
    ]


def bench_cache(seed: int = 0) -> list:
    """(name, us_per_call, hbm_bytes_modeled, note) rows for the gated
    BENCH_2.json ``serving_cache`` section — the artifact-cache
    acceptance scenario (fleet_cached: Zipf(1.1) content skew, 2%
    corrupt-entry faults, a 60 s cache outage) reduced to deterministic
    lower-is-better virtual keys:

      * ``miss_pct``: content misses per 100 consults — growth means the
        cache stopped earning its bytes;
      * ``quarantined_served``: corrupt bytes SERVED. The baseline pins
        this at 0 and check_regression fails any virtual key growing
        from zero, so a single served-corrupt artifact fails CI;
      * ``uncollapsed``: in-flight hits that did NOT coalesce — growth
        means single-flight stampede collapsing broke;
      * the storm's e2e latency tail.

    Hit rate / coalesced / quarantine counts ride in the notes column."""
    s = run_fleet_scenarios(["fleet_cached"], seed=seed)["fleet_cached"]
    c = s["cache"]
    note = (
        f"hit_rate={c['hit_rate']};coalesced={c['coalesced']}"
        f";quarantined={c['quarantined']};evictions={c['evictions']}"
        f";breaker_trips={c['breaker_trips']}"
    )
    return [
        (
            "cache_miss_pct",
            100.0 * c["misses"] / max(c["lookups"], 1),
            None,
            note,
        ),
        (
            "cache_quarantined_served",
            float(c["quarantined_served"]),
            None,
            "acceptance: corrupt bytes are NEVER served (pinned 0)",
        ),
        (
            "cache_uncollapsed",
            float(c["inflight_hits"] - c["coalesced"]),
            None,
            "acceptance: every same-replica in-flight hit coalesces",
        ),
        (
            "cache_lost",
            float(
                s["requests"]["arrived"]
                - s["requests"]["refused"]
                - s["requests"]["no_replica"]
                - s["requests"]["completed"]
                - s["requests"]["demoted"]
                - sum(s["requests"]["rejected"].values())
                - c["coalesced"]
            ),
            None,
            "acceptance: zero lost requests (coalesced is terminal)",
        ),
        ("cache_storm_p99", s["latency_ms"]["p99"] * 1e3, None, note),
    ]


def soak(
    horizon_s: float,
    seed: int = 0,
    fault_rate: float = 0.0,
    content_skew: float | None = None,
    batched: bool = False,
) -> int:
    """The CI soak: one long virtual window of the overload scenario.
    Asserts the hard serving invariants — conservation (zero lost
    requests), typed shedding under overload, and a priority-protected
    interactive tail — and prints the summary. With ``--fault-rate`` the
    same window runs under a transient fault storm at that per-attempt
    rate plus the full resilience policy, and the JSON summary carries
    the retry/breaker counters (the ``resilience`` block). With
    ``--content-skew`` the artifact cache fronts the scheduler and the
    arrival stream draws Zipf-skewed content ids — the summary then
    carries the ``cache`` block and the soak additionally asserts the
    cache invariants (zero corrupt serves, conservation with coalesced
    as a terminal state). With ``--batched`` the same window runs under
    ``SchedulerConfig.batched_dispatch`` — every dispatch group is one
    batched launch — and the identical conservation/shedding invariants
    must hold. Returns a process exit code."""
    scenario = "overload_batched" if batched else "overload"
    if fault_rate > 0.0 or content_skew is not None:
        import dataclasses

        from repro.serving import simulator as sim

        cfg = sim.preset(scenario, seed=seed, horizon_s=horizon_s)
        if fault_rate > 0.0:
            from repro.serving.resilience import (
                BreakerConfig,
                FaultPlan,
                FaultRule,
                ResiliencePolicy,
                RetryPolicy,
            )

            cfg = dataclasses.replace(
                cfg,
                resilience=ResiliencePolicy(
                    retry=RetryPolicy(max_attempts=3, backoff_base_s=0.1,
                                      seed=seed),
                    service_timeout_s={"interactive": 4.0, "standard": 8.0,
                                       "batch": 20.0},
                    breaker=BreakerConfig(trip_after=3, cooldown_s=120.0),
                ),
                fault_plan=FaultPlan(
                    seed=seed,
                    rules=(FaultRule(kind="transient", rate=fault_rate),),
                ),
            )
        if content_skew is not None:
            from repro.serving.cache import CacheConfig

            cfg = dataclasses.replace(
                cfg,
                cache=CacheConfig(capacity_bytes=4 * 1024 * 1024),
                content_skew=content_skew,
                content_universe=128,
            )
        s = sim.simulate(_engine(), cfg).summary()
    else:
        s = run_scenarios([scenario], seed=seed, horizon_s=horizon_s)[scenario]
    print(json.dumps(s, indent=1, sort_keys=True))
    req = s["requests"]
    ok = True
    if not req["conserved"]:
        print("SOAK FAIL: conservation violated", file=sys.stderr)
        ok = False
    if req["arrived"] != req["refused"] + req["admitted"]:
        print("SOAK FAIL: arrivals lost before admission", file=sys.stderr)
        ok = False
    shed = req["refused"] + req["demoted"] + sum(req["rejected"].values())
    if shed == 0:
        print("SOAK FAIL: overload produced no shedding", file=sys.stderr)
        ok = False
    inter = s["classes"].get("interactive")
    if inter and inter["queue_wait_ms"]["p99"] > 5_000.0:
        print("SOAK FAIL: interactive p99 wait above 5 s", file=sys.stderr)
        ok = False
    cache = s.get("cache")
    if content_skew is not None:
        if cache is None:
            print("SOAK FAIL: content skew ran without a cache block",
                  file=sys.stderr)
            ok = False
        else:
            if cache["quarantined_served"] != 0:
                print(
                    f"SOAK FAIL: {cache['quarantined_served']} corrupt "
                    "artifact(s) SERVED",
                    file=sys.stderr,
                )
                ok = False
            if cache["hit_rate"] <= 0.0:
                print("SOAK FAIL: Zipf skew produced no cache hits",
                      file=sys.stderr)
                ok = False
    res = s.get("resilience")
    if fault_rate > 0.0:
        if res is None:
            print("SOAK FAIL: fault storm ran without a resilience block",
                  file=sys.stderr)
            ok = False
        elif res["faulted_requests"] > 0 and res["recovery_rate"] < 0.9:
            print(
                f"SOAK FAIL: recovery rate {res['recovery_rate']} < 0.9 "
                f"under fault rate {fault_rate}",
                file=sys.stderr,
            )
            ok = False
    tail = ""
    if res is not None:
        tail = (
            f" retries={res['retries']} "
            f"faulted={res['faulted_requests']} "
            f"recovery_rate={res['recovery_rate']}"
        )
    if cache is not None:
        tail += (
            f" cache_hit_rate={cache['hit_rate']} "
            f"coalesced={cache['coalesced']} "
            f"quarantined={cache['quarantined']}"
        )
    print(f"\nsoak {'OK' if ok else 'FAILED'}: horizon={s['horizon_s']}s "
          f"arrived={req['arrived']} shed={shed} "
          f"interactive_p99_wait_ms={inter['queue_wait_ms']['p99'] if inter else '-'}"
          + tail)
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--scenario",
        action="append",
        help="preset name (steady|burst|overload, or fleet_* with --fleet); "
        "repeatable; default all",
    )
    ap.add_argument(
        "--fleet",
        action="store_true",
        help="run the replicated-fleet presets (serving/fleet.py) instead "
        "of the single-server ones",
    )
    ap.add_argument("--horizon", type=float, default=None, help="virtual seconds")
    ap.add_argument("--json-out", help="write the per-scenario summaries here")
    ap.add_argument(
        "--soak",
        type=float,
        default=None,
        metavar="SECONDS",
        help="run the overload soak for this many VIRTUAL seconds and "
        "assert serving invariants (CI uses 3600 — one virtual hour)",
    )
    ap.add_argument(
        "--fault-rate",
        type=float,
        default=0.0,
        metavar="RATE",
        help="with --soak: inject transient faults at this per-attempt "
        "rate under the full resilience policy; the JSON summary then "
        "carries the retry/breaker counters and recovery rate",
    )
    ap.add_argument(
        "--content-skew",
        type=float,
        default=None,
        metavar="S",
        help="with --soak: front the scheduler with the artifact cache "
        "(serving/cache.py) and draw Zipf(S)-skewed content ids over a "
        "128-volume universe; the soak then asserts the cache invariants "
        "(zero corrupt serves, conservation with coalesced)",
    )
    ap.add_argument(
        "--batched",
        action="store_true",
        help="with --soak: run the window with batched dispatch enabled "
        "(every admission group serves as ONE batched launch) and assert "
        "the same conservation/shedding invariants",
    )
    args = ap.parse_args(argv)
    if args.soak is not None:
        return soak(
            args.soak,
            seed=args.seed,
            fault_rate=args.fault_rate,
            content_skew=args.content_skew,
            batched=args.batched,
        )

    if args.fleet:
        from repro.serving import fleet as fl

        scenarios = args.scenario or list(fl.FLEET_PRESETS)
        summaries = run_fleet_scenarios(
            scenarios, seed=args.seed, horizon_s=args.horizon
        )
        print(
            "scenario,arrived,refused,admitted,completed,demoted,rejected,"
            "redispatched,replicas,affinity_hit_rate,p50_ms,p99_ms"
        )
        for name, s in summaries.items():
            req = s["requests"]
            print(
                f"{name},{req['arrived']},{req['refused'] + req['no_replica']},"
                f"{req['admitted']},{req['completed']},{req['demoted']},"
                f"{sum(req['rejected'].values())},{req['redispatched']},"
                f"{s['replicas']['created']},{s['affinity']['hit_rate']},"
                f"{s['latency_ms']['p50']},{s['latency_ms']['p99']}"
            )
        if args.json_out:
            with open(args.json_out, "w") as f:
                json.dump(summaries, f, indent=1, sort_keys=True)
            print(f"wrote {args.json_out}")
        return 0

    from repro.serving import simulator as sim

    scenarios = args.scenario or list(sim.PRESETS)
    summaries = run_scenarios(scenarios, seed=args.seed, horizon_s=args.horizon)
    print(
        "scenario,arrived,refused,admitted,completed,demoted,rejected,"
        "p50_ms,p99_ms,throughput_rps,mean_batch_size"
    )
    for name, s in summaries.items():
        req = s["requests"]
        print(
            f"{name},{req['arrived']},{req['refused']},{req['admitted']},"
            f"{req['completed']},{req['demoted']},{sum(req['rejected'].values())},"
            f"{s['latency_ms']['p50']},{s['latency_ms']['p99']},"
            f"{s['throughput_rps']},{s['mean_batch_size']}"
        )
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(summaries, f, indent=1, sort_keys=True)
        print(f"wrote {args.json_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
