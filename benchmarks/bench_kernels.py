"""Kernel micro-benchmarks: us_per_call for the Pallas kernels (interpret
mode on CPU — correctness-path timing) vs the XLA reference implementation,
the streaming-vs-plain executor comparison (the paper's layer-wise disposal
strategy, Fig. 4's inference column), and the registry head-to-head
(``bench_executors``): xla vs pallas_fused vs pallas_megakernel end-to-end
MeshNet forward per paper model. ``bench_traffic`` prints the modeled HBM
bytes per forward at the paper's 256^3 volume for every registered
executor (telemetry/traffic.py) — the measurement behind EXPERIMENTS.md
§Perf H1 (per-layer fusion) and §Perf H9 (depth-first tiling: megakernel
>= 5x under pallas_fused).

Every row is (name, us_per_call, hbm_bytes_modeled, note); bytes are None
where no traffic model applies (training-side oracles).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import executors, meshnet
from repro.core.meshnet import MeshNetConfig, PAPER_MODELS
from repro.core import streaming
from repro.kernels import ops, ref
from repro.telemetry import traffic

KEY = jax.random.PRNGKey(0)

# Registry head-to-head coverage: the headline full-volume model and the
# wide failsafe model (where Cin x Cout taps start to be MXU-shaped).
EXEC_BENCH_MODELS = ("gwm_light", "subvolume_gwm_failsafe")

# Every executor with a traffic model, timed head-to-head.
EXEC_BENCH_BACKENDS = ("xla", "pallas_fused", "pallas_megakernel")

# Storage policies priced (and spot-timed) per backend — "fp32" rows keep
# their legacy un-suffixed key names so the regression gate diffs
# like-for-like; reduced policies get "@<precision>" keys.
BENCH_PRECISIONS = ("bf16", "int8w")

Row = tuple[str, float, "int | None", str]


def _time(fn, *args, iters=3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench() -> list[Row]:
    rows: list[Row] = []
    x = jax.random.normal(KEY, (1, 32, 32, 32, 5))
    w = jax.random.normal(KEY, (3, 3, 3, 5, 5)) * 0.2
    b = jnp.zeros((5,))
    conv_b = traffic.dilated_conv_layer_bytes((32, 32, 32), 5, 5, dilation=8)

    ref_fn = jax.jit(lambda x, w, b: ref.dilated_conv3d(x, w, b, dilation=8))
    rows.append(("dilated_conv3d_xla_ref_32cube", _time(ref_fn, x, w, b), None, "oracle"))
    pal_fn = jax.jit(
        lambda x, w, b: ops.dilated_conv3d(x, w, b, dilation=8, interpret=True)
    )
    rows.append(("dilated_conv3d_pallas_interp_32cube", _time(pal_fn, x, w, b), conv_b, "interpret-mode (correctness path; compiled Mosaic on TPU)"))

    pred = jax.random.randint(KEY, (64, 64, 64), 0, 3)
    truth = jax.random.randint(jax.random.PRNGKey(1), (64, 64, 64), 0, 3)
    from repro.training import losses

    dice_b = 2 * 64**3 * 4  # pred + truth reads; counts are negligible
    rows.append(("dice_xla_ref_64cube", _time(jax.jit(lambda a, b: losses.dice_score(a, b, 3)), pred, truth), None, "oracle"))
    rows.append(("dice_pallas_interp_64cube", _time(lambda a, b: ops.dice(a, b, 3, interpret=True), pred, truth), dice_b, "interpret-mode"))

    cfg = MeshNetConfig()
    p = meshnet.init(KEY, cfg)
    vol = jax.random.normal(KEY, (1, 32, 32, 32))
    shape32 = (32, 32, 32)
    plain = jax.jit(lambda v: meshnet.apply(p, v, cfg))
    rows.append(("meshnet_plain_32cube", _time(plain, vol), traffic.meshnet_xla_bytes(cfg, shape32), "all-layers graph"))
    stream = jax.jit(lambda v: streaming.streaming_apply(p, v, cfg))
    rows.append(("meshnet_streaming_32cube", _time(stream, vol), traffic.meshnet_streaming_bytes(cfg, shape32), "scan-over-layers (paper's layer disposal)"))
    return rows


def bench_executors(
    models: tuple[str, ...] = EXEC_BENCH_MODELS,
    side: int = 16,
    iters: int = 2,
) -> list[Row]:
    """Head-to-head end-to-end MeshNet forward per executor backend.

    For each paper model, times the same (1, side^3) volume through every
    Pallas-capable registry entry. On a CPU host the Pallas paths run in
    interpret mode — orders of magnitude slower, correctness-path numbers
    only; on TPU they are compiled Mosaic kernels and the comparison is
    the one that justifies the production default. The bytes column is
    the modeled HBM traffic *at this benchmark shape* (at 16^3 the halo
    dominates; see ``bench_traffic`` for the paper-volume picture).
    """
    rows: list[Row] = []
    backend = jax.default_backend()
    vol = jax.random.normal(KEY, (1, side, side, side))
    for name in models:
        cfg = PAPER_MODELS[name]
        p = meshnet.init(KEY, cfg)
        for exec_name in EXEC_BENCH_BACKENDS:
            # the registry's cached jit wrapper — the exact callable the
            # pipeline and engine serve with, not a fresh per-loop trace
            jf = executors.jitted_apply(exec_name)
            fn = lambda v, jf=jf, p=p, cfg=cfg: jf(p, v, cfg)
            note = (
                "oracle"
                if exec_name == "xla"
                else f"interpret-mode on {backend} (compiled Mosaic on TPU)"
                if backend != "tpu"
                else "compiled Mosaic"
            )
            hbm = executors.modeled_hbm_bytes(exec_name, cfg, (side,) * 3)
            rows.append(
                (f"meshnet_{name}_{exec_name}_{side}cube", _time(fn, vol, iters=iters), hbm, note)
            )
    # precision spot-checks: the headline model through the megakernel at
    # each reduced policy (same volume, same cached-jit dispatch path)
    cfg = PAPER_MODELS[models[0]]
    p = meshnet.init(KEY, cfg)
    for prec in BENCH_PRECISIONS:
        jf = executors.jitted_apply("pallas_megakernel", precision=prec)
        fn = lambda v, jf=jf, p=p, cfg=cfg: jf(p, v, cfg)
        hbm = executors.modeled_hbm_bytes(
            "pallas_megakernel", cfg, (side,) * 3, precision=prec
        )
        rows.append(
            (
                f"meshnet_{models[0]}_pallas_megakernel_{side}cube@{prec}",
                _time(fn, vol, iters=iters),
                hbm,
                f"precision policy {prec} (kernels/quantize.py)",
            )
        )
    return rows


def bench_traffic(
    models: tuple[str, ...] = EXEC_BENCH_MODELS,
    vol: tuple[int, int, int] = (256, 256, 256),
) -> list[Row]:
    """Modeled HBM bytes per forward at the paper's full volume, for every
    registered executor (no wall-clock: the model is analytic, so this
    runs anywhere — EXPERIMENTS.md §Perf H9's measurement)."""
    rows: list[Row] = []
    side = vol[0]
    for name in models:
        cfg = PAPER_MODELS[name]
        # the retired 27-view conv schedule (variant="views"), kept as the
        # baseline row of the DESIGN.md §2.1 table — not a registered
        # executor, so priced directly from the traffic model
        rows.append(
            (
                f"hbm_{name}_{side}_views_legacy",
                0.0,
                traffic.meshnet_views_bytes(cfg, vol),
                f"modeled at {side}^3 (no timing); retired 27-view schedule",
            )
        )
        for exec_name in executors.names():
            if exec_name.startswith(executors.SHARDED_PREFIX):
                continue  # priced below at explicit slab counts
            hbm = executors.modeled_hbm_bytes(exec_name, cfg, vol)
            note = f"modeled at {side}^3 (no timing)"
            if exec_name == "pallas_megakernel" and hbm is not None:
                fused = executors.modeled_hbm_bytes("pallas_fused", cfg, vol)
                note += f"; {fused / hbm:.1f}x under pallas_fused"
            rows.append((f"hbm_{name}_{side}_{exec_name}", 0.0, hbm, note))
            # per-precision rows (EXPERIMENTS.md H11): the acceptance
            # gate reads the megakernel ratios off this table
            for prec in BENCH_PRECISIONS:
                hb = executors.modeled_hbm_bytes(
                    exec_name, cfg, vol, precision=prec
                )
                pn = f"modeled at {side}^3; precision {prec}"
                if hb is not None and hbm:
                    pn += f", {hb / hbm:.2f}x of fp32"
                rows.append(
                    (f"hbm_{name}_{side}_{exec_name}@{prec}", 0.0, hb, pn)
                )
        # the sharded family (DESIGN.md §2.2): per-device HBM shrinks with
        # the slab count while the ICI halo bill grows one boundary at a
        # time — both modeled, so this prices the paper volume anywhere.
        for n in (2, 4, 8):
            hbm = traffic.meshnet_sharded_bytes("pallas_megakernel", cfg, vol, n)
            coll = traffic.meshnet_collective_bytes(cfg, vol, n)
            rows.append(
                (
                    f"hbm_{name}_{side}_sharded_pallas_megakernel@{n}",
                    0.0,
                    hbm,
                    f"modeled at {side}^3; per-device {hbm // n} HBM bytes, "
                    f"{coll} ICI halo bytes total (EXPERIMENTS.md H10)",
                )
            )
        # the sharded megakernel under int8w: int8 one-shot input fetch +
        # per-slab int8 staging plans. The ICI bill keeps the family-wide
        # activation-width convention (conservative for the int8 fetch —
        # DESIGN.md §2.3).
        hbm = traffic.meshnet_sharded_bytes(
            "pallas_megakernel", cfg, vol, 8, precision="int8w"
        )
        coll = traffic.meshnet_collective_bytes(cfg, vol, 8, precision="int8w")
        rows.append(
            (
                f"hbm_{name}_{side}_sharded_pallas_megakernel@8@int8w",
                0.0,
                hbm,
                f"modeled at {side}^3; precision int8w, {coll} ICI halo "
                "bytes (activation-width convention)",
            )
        )
    return rows
