"""Kernel micro-benchmarks: us_per_call for the Pallas kernels (interpret
mode on CPU — correctness-path timing) vs the XLA reference implementation,
the streaming-vs-plain executor comparison (the paper's layer-wise disposal
strategy, Fig. 4's inference column), and the registry head-to-head
(``bench_executors``): xla vs pallas_fused end-to-end MeshNet forward per
paper model — the measurement behind making the fused path the production
default (EXPERIMENTS.md §Perf H1).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import executors, meshnet
from repro.core.meshnet import MeshNetConfig, PAPER_MODELS
from repro.core import streaming
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)

# Registry head-to-head coverage: the headline full-volume model and the
# wide failsafe model (where Cin x Cout taps start to be MXU-shaped).
EXEC_BENCH_MODELS = ("gwm_light", "subvolume_gwm_failsafe")


def _time(fn, *args, iters=3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench() -> list[tuple[str, float, str]]:
    rows = []
    x = jax.random.normal(KEY, (1, 32, 32, 32, 5))
    w = jax.random.normal(KEY, (3, 3, 3, 5, 5)) * 0.2
    b = jnp.zeros((5,))

    ref_fn = jax.jit(lambda x, w, b: ref.dilated_conv3d(x, w, b, dilation=8))
    rows.append(("dilated_conv3d_xla_ref_32cube", _time(ref_fn, x, w, b), "oracle"))
    pal_fn = jax.jit(
        lambda x, w, b: ops.dilated_conv3d(x, w, b, dilation=8, interpret=True)
    )
    rows.append(("dilated_conv3d_pallas_interp_32cube", _time(pal_fn, x, w, b), "interpret-mode (correctness path; compiled Mosaic on TPU)"))

    pred = jax.random.randint(KEY, (64, 64, 64), 0, 3)
    truth = jax.random.randint(jax.random.PRNGKey(1), (64, 64, 64), 0, 3)
    from repro.training import losses

    rows.append(("dice_xla_ref_64cube", _time(jax.jit(lambda a, b: losses.dice_score(a, b, 3)), pred, truth), "oracle"))
    rows.append(("dice_pallas_interp_64cube", _time(lambda a, b: ops.dice(a, b, 3, interpret=True), pred, truth), "interpret-mode"))

    cfg = MeshNetConfig()
    p = meshnet.init(KEY, cfg)
    vol = jax.random.normal(KEY, (1, 32, 32, 32))
    plain = jax.jit(lambda v: meshnet.apply(p, v, cfg))
    rows.append(("meshnet_plain_32cube", _time(plain, vol), "all-layers graph"))
    stream = jax.jit(lambda v: streaming.streaming_apply(p, v, cfg))
    rows.append(("meshnet_streaming_32cube", _time(stream, vol), "scan-over-layers (paper's layer disposal)"))
    return rows


def bench_executors(
    models: tuple[str, ...] = EXEC_BENCH_MODELS,
    side: int = 16,
    iters: int = 2,
) -> list[tuple[str, float, str]]:
    """Head-to-head end-to-end MeshNet forward per executor backend.

    For each paper model, times the same (1, side^3) volume through the
    "xla" and "pallas_fused" registry entries. On a CPU host the fused path
    runs in Pallas interpret mode — orders of magnitude slower, a
    correctness-path number only; on TPU it is the compiled Mosaic kernel
    and the comparison is the one that justifies the production default.
    """
    rows = []
    backend = jax.default_backend()
    vol = jax.random.normal(KEY, (1, side, side, side))
    for name in models:
        cfg = PAPER_MODELS[name]
        p = meshnet.init(KEY, cfg)
        for exec_name in ("xla", "pallas_fused"):
            # the registry's cached jit wrapper — the exact callable the
            # pipeline and engine serve with, not a fresh per-loop trace
            jf = executors.jitted_apply(exec_name)
            fn = lambda v, jf=jf, p=p, cfg=cfg: jf(p, v, cfg)
            note = (
                "oracle"
                if exec_name == "xla"
                else f"interpret-mode on {backend} (compiled Mosaic on TPU)"
                if backend != "tpu"
                else "compiled Mosaic"
            )
            rows.append(
                (f"meshnet_{name}_{exec_name}_{side}cube", _time(fn, vol, iters=iters), note)
            )
    return rows
